"""Compression-health rule engine over the metrics registry (DESIGN.md §10.5).

SparCML's correctness story is error feedback: clamped/dropped gradient
mass must land in the EF residual and drain back out on later steps
(the global-residual rule, DESIGN.md §9). Nothing enforces that it
*does* drain — a too-small k, a mis-clamped portfolio algorithm, or a
drifting density can grow the residual without bound while the loss
curve still looks plausible for a while. The mass telemetry the
executor now emits (`bucket/*/mass_coverage`, `bucket/*/ef_norm`)
makes the failure observable; this module turns it into ranked,
actionable events.

``HealthMonitor.evaluate()`` runs a fixed set of WINDOWED rules over
whatever the registry currently holds and returns severity-ranked
:class:`HealthEvent` rows (worst first, deterministic order). Each
evaluation also mirrors the events into the registry
(``health/<rule>``) so they ride the normal JSONL/report sinks. Rules:

  ef_growth        per bucket: median ‖r‖ of the most recent window vs
                   the window before it — EF residual mass should hover,
                   not grow geometrically
  coverage_floor   per bucket: recent median ‖topk‖²/‖g+r‖² below the
                   floor means most gradient mass is riding the residual
                   instead of the wire (k too small for the density)
  step_time_p99    recent p99 step wall time vs the preceding window's
                   median — pipelined-runtime regression watch
  serve_slo        p99 of ``serve/<key>_steps`` vs the SLO targets a
                   :class:`repro.serve.ServeConfig` declares
  nonfinite        guarded-step trips (``guard/nonfinite_trips``) since
                   the last evaluation — always critical; feeds the
                   controller's fault demotion (DESIGN.md §12)
  drift_flag       DriftAuditor escalation: a flagged algorithm is a
                   warn; a median measured/predicted ratio beyond
                   flag_ratio² is critical

Everything is host-side reads of already-recorded host scalars: no
device work, no sync points. The driver evaluates at drain barriers and
end-of-run; the serve engine at end-of-run; ``repro.obs.report`` renders
the recorded events as the health timeline.

The advisory side (:meth:`HealthMonitor.advisory`) compresses the event
list into the one decision the AdaptiveController can act on at a drain
barrier: which buckets are critically unhealthy. The controller treats
that as an urgency signal (patience bypass on its next accepted
proposal) — advisory, never a forced plan change.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

SEVERITIES = ("critical", "warn", "info")
_SEV_RANK = {s: i for i, s in enumerate(SEVERITIES)}


@dataclass(frozen=True)
class HealthConfig:
    """Thresholds of the rule engine. ``window`` is the sample count of
    the "recent" window each rule compares against its predecessor;
    rules stay silent until ``min_samples`` fill both sides (no verdicts
    from noise). ``critical_factor`` scales any warn threshold up to its
    critical escalation."""

    window: int = 32
    min_samples: int = 8
    ef_growth_ratio: float = 2.0     # recent/baseline median ‖r‖
    coverage_floor: float = 0.5      # recent median mass coverage
    step_p99_factor: float = 2.0     # recent p99 / baseline median wall
    critical_factor: float = 2.0
    step_time_series: str = "train/step_time_s"


@dataclass(frozen=True)
class HealthEvent:
    """One rule verdict. ``subject`` is the bucket/algorithm/SLO key the
    rule fired on; ``value``/``threshold`` are the measured quantity and
    the bound it crossed (units depend on the rule)."""

    severity: str
    rule: str
    subject: str
    message: str
    value: float
    threshold: float

    def sort_key(self):
        return (_SEV_RANK[self.severity], self.rule, self.subject)


def rank_events(events) -> list[HealthEvent]:
    """Deterministic severity-ranked order: critical first, then warn,
    then info; ties broken by (rule, subject) so identical registries
    always produce the identical list."""
    return sorted(events, key=HealthEvent.sort_key)


def _split_windows(values, window: int, min_samples: int):
    """(baseline, recent) tail split, or None while underfilled. Recent
    is the last ``window`` samples; baseline the ``window`` before them
    (shorter histories split in half so early steps still get a
    verdict once 2*min_samples exist)."""
    n = len(values)
    if n < 2 * min_samples:
        return None
    w = min(window, n // 2)
    return values[-2 * w:-w], values[-w:]


class HealthMonitor:
    """Windowed rules over a :class:`repro.obs.metrics.MetricsRegistry`.

    ``serve_slo`` maps latency keys ("ttft", "tpot", ...) to targets in
    decode-step units — pass ``ServeConfig.slo_targets()``. ``audit`` is
    an optional DriftAuditor. All inputs are read-only; evaluation is
    pure over the registry state plus the monitor's own event history.
    """

    def __init__(self, registry, cfg: HealthConfig = HealthConfig(), *,
                 serve_slo: Optional[dict] = None, audit=None):
        self.registry = registry
        self.cfg = cfg
        self.serve_slo = dict(serve_slo or {})
        self.audit = audit
        self.history: list[HealthEvent] = []
        self._nonfinite_seen = 0

    # -- rule helpers ------------------------------------------------------
    def _bucket_histograms(self, suffix: str):
        pre, post = "bucket/", "/" + suffix
        for name in sorted(self.registry.metrics):
            if name.startswith(pre) and name.endswith(post):
                m = self.registry.metrics[name]
                if getattr(m, "kind", None) == "histogram" and m.values:
                    yield name[len(pre):-len(post)], m.values

    def _escalate(self, value, warn_at, *, above: bool) -> Optional[str]:
        """warn/critical/None for a threshold crossed from above or
        below (coverage is a floor, everything else a ceiling)."""
        crit = (warn_at * self.cfg.critical_factor if above
                else warn_at / self.cfg.critical_factor)
        if above:
            if value >= crit:
                return "critical"
            return "warn" if value >= warn_at else None
        if value <= crit:
            return "critical"
        return "warn" if value <= warn_at else None

    # -- rules -------------------------------------------------------------
    def _rule_ef_growth(self):
        for bucket, vals in self._bucket_histograms("ef_norm"):
            split = _split_windows(vals, self.cfg.window,
                                   self.cfg.min_samples)
            if split is None:
                continue
            base, recent = split
            m0 = float(np.median(base))
            m1 = float(np.median(recent))
            ratio = m1 / max(m0, 1e-30)
            sev = self._escalate(ratio, self.cfg.ef_growth_ratio, above=True)
            if sev:
                yield HealthEvent(
                    sev, "ef_growth", bucket,
                    f"EF residual norm grew {ratio:.2f}x over the last "
                    f"window ({m0:.3g} -> {m1:.3g}): compressed mass is "
                    "accumulating instead of draining (k too small or "
                    "clamp fold runaway)", ratio, self.cfg.ef_growth_ratio)

    def _rule_coverage_floor(self):
        for bucket, vals in self._bucket_histograms("mass_coverage"):
            if len(vals) < self.cfg.min_samples:
                continue
            recent = vals[-min(self.cfg.window, len(vals)):]
            med = float(np.median(recent))
            sev = self._escalate(med, self.cfg.coverage_floor, above=False)
            if sev:
                yield HealthEvent(
                    sev, "coverage_floor", bucket,
                    f"median compressed-mass coverage {med:.3f} under the "
                    f"{self.cfg.coverage_floor:.2f} floor: most gradient "
                    "mass rides the EF residual, not the wire",
                    med, self.cfg.coverage_floor)

    def _rule_step_time(self):
        m = self.registry.metrics.get(self.cfg.step_time_series)
        vals = list(getattr(m, "data", None) or getattr(m, "values", []) or [])
        split = _split_windows(vals, self.cfg.window, self.cfg.min_samples)
        if split is None:
            return
        base, recent = split
        baseline = float(np.median(base))
        p99 = float(np.percentile(np.asarray(recent, dtype=np.float64), 99))
        factor = p99 / max(baseline, 1e-30)
        sev = self._escalate(factor, self.cfg.step_p99_factor, above=True)
        if sev:
            yield HealthEvent(
                sev, "step_time_p99", self.cfg.step_time_series,
                f"recent p99 step time {p99 * 1e3:.3g} ms is {factor:.2f}x "
                f"the preceding window's median ({baseline * 1e3:.3g} ms)",
                factor, self.cfg.step_p99_factor)

    def _rule_serve_slo(self):
        for key in sorted(self.serve_slo):
            target = float(self.serve_slo[key])
            m = self.registry.metrics.get(f"serve/{key}_steps")
            vals = getattr(m, "values", None)
            if not vals:
                continue
            p99 = float(np.percentile(np.asarray(vals, np.float64), 99))
            sev = self._escalate(p99, target, above=True)
            if sev:
                yield HealthEvent(
                    sev, "serve_slo", key,
                    f"serve {key} p99 of {p99:.3g} decode steps misses the "
                    f"{target:.3g}-step SLO target", p99, target)

    def _rule_nonfinite(self):
        """Guard trips since the last evaluation (DESIGN.md §12.2). The
        guarded step already skipped the apply and preserved EF/optimizer
        state; this verdict is the drain-barrier signal the
        AdaptiveController keys its fault demotion on."""
        m = self.registry.metrics.get("guard/nonfinite_trips")
        total = int(getattr(m, "value", 0) or 0)
        new = total - self._nonfinite_seen
        self._nonfinite_seen = total
        if new <= 0:
            return
        yield HealthEvent(
            "critical", "nonfinite", "grads",
            f"{new} guarded step(s) tripped on non-finite gradients since "
            f"the last evaluation ({total} total): apply skipped, EF "
            "residuals and optimizer state preserved", float(new), 0.0)

    def _rule_drift_flag(self):
        if self.audit is None or not len(self.audit):
            return
        fr = self.audit.flag_ratio
        for alg, st in self.audit.per_algorithm().items():
            if not st["flagged"]:
                continue
            med = st["median_ratio"]
            # escalation: a flag is a warn; a ratio beyond flag_ratio²
            # means the cost model is off by more than one whole trust
            # band in either direction — critical.
            beyond = med >= fr * fr or med <= 1.0 / (fr * fr)
            yield HealthEvent(
                "critical" if beyond else "warn", "drift_flag", alg,
                f"cost-model drift: median measured/predicted ratio "
                f"{med:.3g} outside [{1.0 / fr:.2g}, {fr:.2g}]", med, fr)

    # -- engine ------------------------------------------------------------
    def evaluate(self) -> list[HealthEvent]:
        """Run every rule once; return the ranked verdicts and mirror
        them into the registry as ``health/<rule>`` events."""
        events: list[HealthEvent] = []
        for rule in (self._rule_ef_growth, self._rule_coverage_floor,
                     self._rule_step_time, self._rule_serve_slo,
                     self._rule_nonfinite, self._rule_drift_flag):
            events.extend(rule() or ())
        ranked = rank_events(events)
        for ev in ranked:
            self.registry.event(f"health/{ev.rule}", severity=ev.severity,
                                subject=ev.subject, value=ev.value,
                                threshold=ev.threshold, message=ev.message)
        self.history.extend(ranked)
        return ranked

    def advisory(self, events: Optional[list] = None) -> dict:
        """Compress verdicts into the drain-barrier advisory the
        AdaptiveController consumes: the critically-unhealthy buckets
        and the worst severity seen. Uses the latest evaluation when
        ``events`` is omitted (empty advisory before the first one)."""
        evs = self.history if events is None else events
        buckets = sorted({e.subject for e in evs
                          if e.severity == "critical"
                          and e.rule in ("ef_growth", "coverage_floor")})
        worst = min((e.severity for e in evs), default=None,
                    key=lambda s: _SEV_RANK[s])
        return {"critical_buckets": buckets, "worst": worst,
                "n_events": len(evs)}

    def summary(self) -> str:
        """Aligned terminal table of the accumulated verdicts."""
        if not self.history:
            return "  health: no findings"
        lines = []
        for ev in rank_events(self.history):
            lines.append(f"  [{ev.severity:<8}] {ev.rule:<15} "
                         f"{ev.subject:<24} {ev.message}")
        return "\n".join(lines)
