"""Bounded flight recorder: crash diagnostics for driver and serve runs.

A long pipelined run that dies — an exception deep in a compiled step,
a watchdog straggler storm, a SIGTERM from the cluster scheduler —
historically left nothing behind: the trace and metrics JSONL are only
written by the end-of-run export. The :class:`FlightRecorder` is the
aviation-style answer (DESIGN.md §10.6): a fixed-capacity ring of the
most recent activity that can be dumped ATOMICALLY to ``blackbox.json``
at any moment, from any exit path.

What a dump contains (everything bounded by ``capacity``):

  notes        the recorder's own ring — one entry per retired driver
               unit / serve decode step (step index, loss/occupancy,
               wall time), appended by the runtime host loops
  trace_tail   the last N Chrome-trace events from the attached tracer
  event_tail   the last N structured events from the metrics registry
  series_tail  the last N samples of every Series metric
  metrics      full counter/gauge values + histogram snapshots (these
               are already O(1)-ish summaries)

Dump triggers, wired by the runtime driver and serve engine:

  exception    ``run_pipelined``/``ContinuousServeEngine.run`` dump
               before re-raising (and before a restore_fn restart)
  watchdog     the driver's straggler watchdog fires
  signal       ``install_signal_handlers`` (opt-in, main thread only)
               dumps on SIGTERM/SIGINT-style signals, then chains to
               the previous handler

The write is tmp-file + fsync + ``os.replace``: a reader either sees a
complete parseable JSON document or the previous one — never a torn
file. Dumping is idempotent and cheap (host-side snapshots only), so
repeated triggers just refresh the same path.
"""
from __future__ import annotations

import json
import os
import signal as _signal
import threading
import time
from collections import deque
from typing import Optional

from repro.obs.metrics import _jsonable


class FlightRecorder:
    """Ring buffer + atomic ``blackbox.json`` dumper.

    ``obs`` is the :class:`repro.obs.Observability` handle whose tracer
    and registry get snapshotted into each dump; the recorder works
    (notes ring only) with the OFF handle too. Thread-safe: the driver's
    retire closure and a signal handler may race a dump."""

    def __init__(self, path: str = "blackbox.json", capacity: int = 256,
                 obs=None):
        from repro.obs import resolve

        self.path = str(path)
        self.capacity = max(1, int(capacity))
        self.obs = resolve(obs)
        self.notes: deque = deque(maxlen=self.capacity)
        self.dumps = 0
        self.last_reason: Optional[str] = None
        self._born = time.time()
        self._lock = threading.Lock()
        self._prev_handlers: dict = {}

    # -- ring --------------------------------------------------------------
    def note(self, kind: str, /, **fields) -> None:
        """Append one bounded ring entry (host scalars only — callers
        pass floats/ints they already hold; never a device value)."""
        self.notes.append({
            "kind": kind, "t": time.time() - self._born,
            **{k: _jsonable(v) for k, v in fields.items()},
        })

    # -- snapshot + dump ---------------------------------------------------
    def snapshot(self, reason: str) -> dict:
        cap = self.capacity
        reg = self.obs.metrics
        metrics: dict = {}
        series_tail: dict = {}
        for name in sorted(reg.metrics):
            m = reg.metrics[name]
            if m.kind == "series":
                series_tail[name] = _jsonable(m.data[-cap:])
            else:
                metrics[name] = {"kind": m.kind, **_jsonable(m.snapshot())}
        return {
            "kind": "blackbox",
            "reason": reason,
            "wall_time": time.time(),
            "uptime_s": time.time() - self._born,
            "pid": os.getpid(),
            "capacity": cap,
            "notes": list(self.notes),
            "trace_tail": _jsonable(self.obs.tracer.events[-cap:])
            if self.obs.trace_on else [],
            "event_tail": _jsonable(reg.events[-cap:]),
            "series_tail": series_tail,
            "metrics": metrics,
        }

    def dump(self, reason: str) -> str:
        """Atomically (re)write ``blackbox.json``. Never raises from a
        teardown path the caller can't handle — IO failures surface as
        the returned path vs a raised error only outside handlers."""
        with self._lock:
            doc = self.snapshot(reason)
            d = os.path.dirname(os.path.abspath(self.path))
            os.makedirs(d, exist_ok=True)
            tmp = os.path.join(
                d, f".{os.path.basename(self.path)}.tmp.{os.getpid()}")
            with open(tmp, "w") as f:
                json.dump(doc, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)
            self.dumps += 1
            self.last_reason = reason
            return self.path

    def _safe_dump(self, reason: str) -> Optional[str]:
        try:
            return self.dump(reason)
        except Exception:
            return None

    # -- signal trigger ----------------------------------------------------
    def install_signal_handlers(self, signals=("SIGTERM",)) -> list:
        """Dump on delivery of each named signal, then chain to the
        previously-installed handler (or re-raise the default action for
        terminating signals so exit codes stay honest). Main thread
        only — Python restricts ``signal.signal`` to it; callers off the
        main thread get an empty install instead of a crash."""
        installed = []
        if threading.current_thread() is not threading.main_thread():
            return installed
        for name in signals:
            signum = getattr(_signal, name, None)
            if signum is None:
                continue

            def _handler(num, frame, _name=name):
                self._safe_dump(f"signal:{_name}")
                prev = self._prev_handlers.get(num)
                if callable(prev):
                    prev(num, frame)
                elif prev == _signal.SIG_DFL:
                    _signal.signal(num, _signal.SIG_DFL)
                    _signal.raise_signal(num)

            self._prev_handlers[signum] = _signal.getsignal(signum)
            _signal.signal(signum, _handler)
            installed.append(name)
        return installed

    def uninstall_signal_handlers(self) -> None:
        for signum, prev in self._prev_handlers.items():
            try:
                _signal.signal(signum, prev)
            except (ValueError, TypeError):
                pass
        self._prev_handlers = {}
