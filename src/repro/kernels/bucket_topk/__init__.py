from repro.kernels.bucket_topk.ops import bucket_topk  # noqa: F401
