"""Pallas TPU kernel: per-bucket top-k selection + fused error-feedback.

One grid step processes TB buckets (rows). Working set per step:
  x tile (TB, B) + magnitude copy + one-hot accumulation -> ~3*TB*B*4 bytes
kept well under VMEM (16 MB). B is a multiple of 128 (lane width) and the
selection loop is unrolled k times (k is small: 2..64), each iteration one
row-argmax on the VPU followed by a compare-select; there is no serialized
scatter anywhere — TPU-native by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")  # plain python float: not captured as a traced const


def _kernel(x_ref, val_ref, lidx_ref, res_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)  # (TB, B)
    tb, b = x.shape
    mag = jnp.abs(x)
    iota = jax.lax.broadcasted_iota(jnp.int32, (tb, b), 1)
    sel = jnp.zeros((tb, b), jnp.bool_)
    idxs = []
    # Unrolled iterative argmax: identical tie-break (lowest index) as
    # jax.lax.top_k in ref.py.
    for _ in range(k):
        j = jnp.argmax(mag, axis=1).astype(jnp.int32)  # (TB,)
        hit = iota == j[:, None]  # (TB, B) one-hot
        sel = sel | hit
        mag = jnp.where(hit, NEG_INF, mag)
        idxs.append(j)
    lidx = jnp.stack(idxs, axis=1)  # (TB, k) in selection order
    # Reorder by ascending local index (cheap k*log k on rows of length k).
    lidx = jnp.sort(lidx, axis=1)
    # Gather selected values with one-hot contractions (k small).
    onehot = (lidx[:, :, None] == iota[:, None, :]).astype(x.dtype)  # (TB,k,B)
    val = jnp.sum(onehot * x[:, None, :], axis=2)  # (TB, k)
    val_ref[...] = val.astype(val_ref.dtype)
    lidx_ref[...] = lidx
    res_ref[...] = jnp.where(sel, 0, x_ref[...])


def bucket_topk_pallas(x: jax.Array, k: int, *, interpret: bool = True, tb: int | None = None):
    """x: (nb, B) -> (val (nb,k), lidx (nb,k) i32, residual (nb,B))."""
    nb, b = x.shape
    if tb is None:
        # Target ~64K elements of x per grid step.
        tb = max(1, min(nb, 65536 // b))
        while nb % tb:
            tb -= 1
    grid = (nb // tb,)
    return pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((tb, b), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tb, k), lambda i: (i, 0)),
            pl.BlockSpec((tb, k), lambda i: (i, 0)),
            pl.BlockSpec((tb, b), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, k), x.dtype),
            jax.ShapeDtypeStruct((nb, k), jnp.int32),
            jax.ShapeDtypeStruct((nb, b), x.dtype),
        ],
        interpret=interpret,
    )(x)
