"""Pure-jnp oracle for bucket_topk.

Semantics (shared with the kernel):
  input  x:   (nb, B) values
  output val: (nb, k) selected values, ordered by ascending local index
         lidx:(nb, k) int32 local indices (within bucket), ascending
         res: (nb, B) residual = x with selected entries zeroed

Selection: top-k by |x| per bucket; ties broken toward the LOWER index
(both jax.lax.top_k and iterative argmax obey this, so kernel and ref
agree exactly).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bucket_topk_ref(x: jax.Array, k: int):
    nb, b = x.shape
    mag = jnp.abs(x)
    _, lidx = jax.lax.top_k(mag, k)  # (nb, k), ties -> lower index first
    lidx = jnp.sort(lidx, axis=1).astype(jnp.int32)
    val = jnp.take_along_axis(x, lidx, axis=1)
    iota = jnp.arange(b, dtype=jnp.int32)[None, None, :]  # (1, 1, B)
    sel_mask = jnp.any(lidx[:, :, None] == iota, axis=1)  # (nb, B)
    res = jnp.where(sel_mask, 0, x)
    return val, lidx, res
