"""Public jit'd wrapper for bucket_topk with implementation dispatch.

impl='auto'   -> Pallas (compiled) on TPU, pure-jnp ref elsewhere (CPU/GPU).
impl='pallas' -> Pallas kernel; interpret mode is forced off-TPU so the
                 kernel body runs (slowly but exactly) on CPU for validation.
impl='ref'    -> pure-jnp oracle.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.bucket_topk.kernel import bucket_topk_pallas
from repro.kernels.bucket_topk.ref import bucket_topk_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("k", "impl"))
def bucket_topk(x: jax.Array, k: int, impl: str = "auto"):
    """Per-bucket top-|k| select/compact. x: (nb, B).

    Returns (val (nb,k), lidx (nb,k) i32 ascending, residual (nb,B)).
    """
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return tuple(bucket_topk_ref(x, k))
    return tuple(bucket_topk_pallas(x, k, interpret=not _on_tpu()))
