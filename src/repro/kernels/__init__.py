"""Pallas TPU kernels for SparCML's compute hot-spots.

The paper (§8.3): "Top-k selection and quantization are implemented using
optimized GPU kernels". These are the TPU-native equivalents:

- ``bucket_topk``   — per-bucket top-k selection + compaction + fused
                      error-feedback residual (Alg. 2 lines 1-3).
- ``qsgd_pack``     — QSGD bucketed stochastic quantization + bit-packing (§6).
- ``qsgd_unpack``   — inverse of qsgd_pack.
- ``bucket_scatter``— stream densification via one-hot contraction (MXU
                      friendly; TPU adaptation of CPU/GPU scatter-add).

Each kernel directory holds ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd public wrapper with impl dispatch) and ``ref.py``
(pure-jnp oracle). Kernels are validated in interpret mode on CPU; on real
TPU hardware the same code path runs compiled (interpret=False).
"""
