"""Public jit'd wrapper for qsgd_unpack."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.qsgd_unpack.kernel import qsgd_unpack_pallas
from repro.kernels.qsgd_unpack.ref import qsgd_unpack_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("bits", "out_dtype", "impl"))
def qsgd_unpack(
    packed: jax.Array,
    scale: jax.Array,
    bits: int = 4,
    out_dtype=jnp.float32,
    impl: str = "auto",
):
    """packed u32 (nb, W), scale (nb, 1) -> xhat (nb, W*32//bits)."""
    assert bits in (2, 4, 8), bits
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return qsgd_unpack_ref(packed, scale, bits, out_dtype)
    return qsgd_unpack_pallas(packed, scale, bits, out_dtype, interpret=not _on_tpu())
