"""Pallas TPU kernel: QSGD unpack + dequantize (lane-wise shift+mask)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.qsgd_pack.ref import levels


def _kernel(packed_ref, scale_ref, out_ref, *, bits: int):
    packed = packed_ref[...]  # (TB, W) uint32
    tb, w = packed.shape
    vpw = 32 // bits
    s = levels(bits)
    mask = jnp.uint32(2**bits - 1)
    shifts = (jax.lax.broadcasted_iota(jnp.uint32, (tb, w, vpw), 2)
              * jnp.uint32(bits))
    biased = (packed[:, :, None] >> shifts) & mask
    code = biased.astype(jnp.int32) - s
    xhat = code.astype(jnp.float32) / s * scale_ref[...][:, :, None]
    out_ref[...] = xhat.reshape(tb, w * vpw).astype(out_ref.dtype)


def qsgd_unpack_pallas(
    packed: jax.Array,
    scale: jax.Array,
    bits: int,
    out_dtype=jnp.float32,
    *,
    interpret: bool = True,
    tb: int | None = None,
):
    nb, w = packed.shape
    vpw = 32 // bits
    bq = w * vpw
    if tb is None:
        tb = max(1, min(nb, 65536 // bq))
        while nb % tb:
            tb -= 1
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid=(nb // tb,),
        in_specs=[
            pl.BlockSpec((tb, w), lambda i: (i, 0)),
            pl.BlockSpec((tb, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tb, bq), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bq), out_dtype),
        interpret=interpret,
    )(packed, scale)
