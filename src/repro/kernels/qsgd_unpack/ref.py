"""Pure-jnp oracle for QSGD unpack+dequantize (inverse of qsgd_pack)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.qsgd_pack.ref import levels


def qsgd_unpack_ref(packed: jax.Array, scale: jax.Array, bits: int, out_dtype=jnp.float32):
    nb, w = packed.shape
    vpw = 32 // bits
    s = levels(bits)
    mask = jnp.uint32(2**bits - 1)
    shifts = (jnp.arange(vpw, dtype=jnp.uint32) * bits)[None, None, :]
    biased = (packed[:, :, None] >> shifts) & mask  # (nb, w, vpw)
    code = biased.astype(jnp.int32) - s
    xhat = code.astype(jnp.float32) / s * scale[:, :, None]
    return xhat.reshape(nb, w * vpw).astype(out_dtype)
