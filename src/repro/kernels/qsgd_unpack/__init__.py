from repro.kernels.qsgd_unpack.ops import qsgd_unpack  # noqa: F401
