"""Pallas TPU kernel: densify bucketed sparse streams WITHOUT scatter.

TPU adaptation (DESIGN.md §2.1): serialized scatter-add is the natural
CPU/GPU implementation but is slow on TPU. Because SparCML streams are
bucket-uniform (k entries per B-wide bucket), densification is a one-hot
contraction:   dense[r, :] = Σ_j val[r, j] * (iota == lidx[r, j])
i.e. a (1,k)x(k,B) matmul per row — MXU/VPU work, no data-dependent stores.

VMEM per grid step: onehot (TB, k, B) f32 dominates; TB is tiled so
TB*k*B*4 ≤ ~2 MB.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(lidx_ref, val_ref, out_ref):
    lidx = lidx_ref[...]  # (TB, k)
    val = val_ref[...].astype(jnp.float32)  # (TB, k)
    tb, k = lidx.shape
    b = out_ref.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (tb, k, b), 2)
    onehot = (iota == lidx[:, :, None]).astype(jnp.float32)  # OOB never matches
    out_ref[...] = jnp.sum(val[:, :, None] * onehot, axis=1).astype(out_ref.dtype)


def bucket_scatter_pallas(
    lidx: jax.Array,
    val: jax.Array,
    b: int,
    *,
    interpret: bool = True,
    tb: int | None = None,
):
    nb, k = lidx.shape
    if tb is None:
        tb = max(1, min(nb, (2 * 1024 * 1024 // 4) // max(1, k * b)))
        while nb % tb:
            tb -= 1
    return pl.pallas_call(
        _kernel,
        grid=(nb // tb,),
        in_specs=[
            pl.BlockSpec((tb, k), lambda i: (i, 0)),
            pl.BlockSpec((tb, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tb, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, b), val.dtype),
        interpret=interpret,
    )(lidx, val)
