"""Public jit'd wrapper for bucket_scatter."""
from __future__ import annotations

import functools

import jax

from repro.kernels.bucket_scatter.kernel import bucket_scatter_pallas
from repro.kernels.bucket_scatter.ref import bucket_scatter_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("b", "impl"))
def bucket_scatter(lidx: jax.Array, val: jax.Array, b: int, impl: str = "auto"):
    """Densify per-bucket streams: (nb,k) idx/val -> (nb,B) dense (adds dups,
    drops OOB sentinel indices)."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return bucket_scatter_ref(lidx, val, b)
    return bucket_scatter_pallas(lidx, val, b, interpret=not _on_tpu())
