from repro.kernels.bucket_scatter.ops import bucket_scatter  # noqa: F401
