"""Pure-jnp oracle for bucket_scatter (stream densification).

  lidx: (nb, k) int32 local indices in [0, B) — may contain duplicates
        (duplicates accumulate) or the OOB sentinel (>= B, dropped)
  val:  (nb, k)
  -> dense (nb, B) with dense[r, lidx[r, j]] += val[r, j]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bucket_scatter_ref(lidx: jax.Array, val: jax.Array, b: int):
    nb, k = lidx.shape
    out = jnp.zeros((nb, b), val.dtype)
    rows = jnp.broadcast_to(jnp.arange(nb, dtype=jnp.int32)[:, None], (nb, k))
    return out.at[rows, lidx].add(val, mode="drop")
