"""Public jit'd wrapper for qsgd_pack (see ref.py for semantics)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.qsgd_pack.kernel import qsgd_pack_pallas
from repro.kernels.qsgd_pack.ref import qsgd_pack_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("bits", "scale_mode", "impl"))
def qsgd_pack(
    x: jax.Array,
    rand: jax.Array,
    bits: int = 4,
    scale_mode: str = "l2",
    impl: str = "auto",
):
    """Quantize+pack buckets. x, rand: (nb, Bq) -> (packed u32 (nb, Bq*bits/32),
    scale f32 (nb, 1))."""
    assert bits in (2, 4, 8), bits
    assert x.shape[1] % (32 // bits) == 0
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return tuple(qsgd_pack_ref(x, rand, bits, scale_mode))
    return tuple(qsgd_pack_pallas(x, rand, bits, scale_mode, interpret=not _on_tpu()))
