"""Pure-jnp oracle for QSGD bucketed stochastic quantization + packing.

QSGD (paper §6, [Alistarh et al. 2017]): split into buckets of Bq entries,
one full-precision scale per bucket, each entry stochastically rounded to
s = 2^(bits-1) - 1 signed levels and bit-packed (32//bits codes per u32).

Shared semantics:
  x:    (nb, Bq) float
  rand: (nb, Bq) uint32 — stochastic-rounding noise (explicit operand so the
        kernel is deterministic + testable; see DESIGN.md §5.3)
  -> packed (nb, Bq*bits//32) uint32, scale (nb, 1) float32

Code for entry v with scale σ:  level = floor(|v|/σ * s + u), u∈[0,1);
stored biased: code = sign(v)*level + s ∈ [0, 2s]. σ is the bucket L2 norm
(QSGD) or max-norm (scale_mode='max').
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

U32_TO_UNIT = float(2.0**-32)


def levels(bits: int) -> int:
    return 2 ** (bits - 1) - 1


def bucket_scale(x: jax.Array, scale_mode: str) -> jax.Array:
    if scale_mode == "l2":
        return jnp.sqrt(jnp.sum(x.astype(jnp.float32) ** 2, axis=1, keepdims=True))
    if scale_mode == "max":
        return jnp.max(jnp.abs(x.astype(jnp.float32)), axis=1, keepdims=True)
    raise ValueError(scale_mode)


def qsgd_pack_ref(x: jax.Array, rand: jax.Array, bits: int, scale_mode: str = "l2"):
    nb, bq = x.shape
    vpw = 32 // bits
    s = levels(bits)
    xf = x.astype(jnp.float32)
    scale = bucket_scale(xf, scale_mode)  # (nb, 1)
    safe = jnp.where(scale > 0, scale, 1.0)
    u = rand.astype(jnp.float32) * U32_TO_UNIT
    level = jnp.floor(jnp.abs(xf) / safe * s + u)
    level = jnp.clip(level, 0, s).astype(jnp.int32)
    code = jnp.where(xf < 0, -level, level) + s  # biased, in [0, 2s]
    code = jnp.where(scale > 0, code, s).astype(jnp.uint32)
    shifts = (jnp.arange(vpw, dtype=jnp.uint32) * bits)[None, None, :]
    packed = jnp.sum(
        code.reshape(nb, bq // vpw, vpw) << shifts, axis=2, dtype=jnp.uint32
    )
    return packed, scale
