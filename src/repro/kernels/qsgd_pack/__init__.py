from repro.kernels.qsgd_pack.ops import qsgd_pack  # noqa: F401
