"""Pallas TPU kernel: QSGD quantize + bit-pack.

Grid over bucket rows; each step quantizes TB buckets of Bq entries.
VMEM per step: x tile + rand tile + packed tile ≈ TB*Bq*9 bytes — tiled to
stay ≤ ~1 MB. The pack step is a lane-wise shift+add over a (TB, W, vpw)
reshape: pure VPU work, no gathers.

Stochastic-rounding noise arrives as an explicit uint32 operand (portable,
reproducible, interpret-testable). On real TPU this can be swapped for
pltpu.prng_random_bits seeded per grid step — flagged, not default.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.qsgd_pack.ref import U32_TO_UNIT, levels


def _kernel(x_ref, rand_ref, packed_ref, scale_ref, *, bits: int, scale_mode: str):
    x = x_ref[...].astype(jnp.float32)  # (TB, Bq)
    tb, bq = x.shape
    vpw = 32 // bits
    s = levels(bits)
    if scale_mode == "l2":
        scale = jnp.sqrt(jnp.sum(x * x, axis=1, keepdims=True))
    else:
        scale = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    safe = jnp.where(scale > 0, scale, 1.0)
    u = rand_ref[...].astype(jnp.float32) * U32_TO_UNIT
    level = jnp.floor(jnp.abs(x) / safe * s + u)
    level = jnp.clip(level, 0, s).astype(jnp.int32)
    code = jnp.where(x < 0, -level, level) + s
    code = jnp.where(scale > 0, code, s).astype(jnp.uint32)
    shifts = (jax.lax.broadcasted_iota(jnp.uint32, (tb, bq // vpw, vpw), 2)
              * jnp.uint32(bits))
    packed_ref[...] = jnp.sum(
        code.reshape(tb, bq // vpw, vpw) << shifts, axis=2, dtype=jnp.uint32
    )
    scale_ref[...] = scale


def qsgd_pack_pallas(
    x: jax.Array,
    rand: jax.Array,
    bits: int,
    scale_mode: str = "l2",
    *,
    interpret: bool = True,
    tb: int | None = None,
):
    nb, bq = x.shape
    vpw = 32 // bits
    w = bq // vpw
    if tb is None:
        tb = max(1, min(nb, 65536 // bq))
        while nb % tb:
            tb -= 1
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, scale_mode=scale_mode),
        grid=(nb // tb,),
        in_specs=[
            pl.BlockSpec((tb, bq), lambda i: (i, 0)),
            pl.BlockSpec((tb, bq), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, w), lambda i: (i, 0)),
            pl.BlockSpec((tb, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, w), jnp.uint32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, rand)
