"""JAX version-compatibility shims.

The repo targets the current JAX surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``); pinned
container builds may ship an older JAX where those names do not exist.
Every version-dependent import lives HERE — library code, tests, and
benchmarks import :func:`make_mesh` / :func:`shard_map` from this module
(or via ``repro.launch.mesh``) instead of touching ``jax.*`` directly.

Nothing in this module touches device state at import time.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax

try:  # new builds
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # old builds: make_mesh has no axis_types kwarg at all
    AxisType = None

HAS_AXIS_TYPE = AxisType is not None
HAS_JAX_SHARD_MAP = hasattr(jax, "shard_map")


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None):
    """``jax.make_mesh`` with ``AxisType.Auto`` axes where supported.

    Older builds have no axis-type concept; plain meshes behave identically
    for every use in this repo (explicit shard_map manual/auto sets are
    passed separately — see :func:`shard_map`).
    """
    kwargs: dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    if HAS_AXIS_TYPE:
        kwargs["axis_types"] = (AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False,
              axis_names: Optional[set] = None):
    """Signature adapter over ``jax.shard_map`` / legacy experimental API.

    axis_names: the MANUAL axes; every other mesh axis stays auto (XLA
    keeps inserting its collectives for them). None = manual over all axes.
    check_vma maps to the legacy ``check_rep``.
    """
    if HAS_JAX_SHARD_MAP:
        kw: dict[str, Any] = dict(mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto: frozenset = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)


def ambient_mesh_shape() -> dict:
    """{axis: size} of the mesh currently in scope (trace-time), {} when
    none. New builds expose jax.sharding.get_abstract_mesh; old builds
    track the ambient mesh in thread resources."""
    try:
        from jax.sharding import get_abstract_mesh  # type: ignore

        return dict(get_abstract_mesh().shape)
    except Exception:
        pass
    try:
        from jax._src import mesh as _mesh_lib

        m = _mesh_lib.thread_resources.env.physical_mesh
        return {} if m is None or m.empty else dict(m.shape)
    except Exception:
        return {}


def cost_analysis(compiled) -> dict:
    """Compiled.cost_analysis() as a flat dict on every JAX version
    (older builds return a one-element list of dicts per program)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return ca


def partial_manual_collectives_broken(mesh, manual_axes) -> bool:
    """True when explicit collectives other than psum abort inside a
    PARTIAL-manual shard_map on this backend.

    The XLA-CPU SPMD partitioner in older builds hard-aborts (CHECK
    failure on manual subgroups) for all_to_all / all_gather / ppermute
    lowered inside a shard_map that leaves some mesh axes auto; psum is
    the one collective that survives. Real TPU backends are fine, and
    FULLY-manual regions are fine everywhere. The comm executor swaps in
    psum-emulated collectives when this returns True (DESIGN.md §4).
    """
    auto_axes = set(mesh.axis_names) - set(manual_axes)
    if all(mesh.shape[a] == 1 for a in auto_axes):
        # Fully manual (or trivially-auto: size-1 axes create no real
        # subgroup partitioning): native collectives always work.
        return False
    return jax.default_backend() == "cpu" and not HAS_JAX_SHARD_MAP
