"""Deterministic, resumable, sharded data pipeline.

Synthetic token streams keyed by (seed, step, host) so that:
* restarts resume exactly (checkpoint stores the step),
* elastic resizes re-partition deterministically (each host regenerates
  its shard from the global key — no data server),
* straggler mitigation can SKIP a step globally (every host agrees on the
  skipped step id without communication).

Real deployments would swap `synthetic_batch` for a tokenized shard reader
with the same (seed, step) contract.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 1234
    kind: str = "lm"          # lm | audio | vlm
    frontend_dim: int = 0     # audio frame-embedding dim
    num_image_tokens: int = 0
    vision_dim: int = 0


def synthetic_batch(cfg: DataConfig, step: int) -> dict:
    """Batch for global step `step` (host-independent content; callers doing
    multi-host would slice their rows). Markov-ish token stream so the LM
    loss actually decreases during convergence tests."""
    rng = np.random.default_rng(cfg.seed + step * 1_000_003)
    b, s = cfg.global_batch, cfg.seq_len
    # structured stream: a random walk over the vocab with local coherence
    start = rng.integers(0, cfg.vocab_size, size=(b, 1))
    steps = rng.integers(-3, 4, size=(b, s - 1))
    toks = np.concatenate([start, start + np.cumsum(steps, axis=1)], axis=1)
    toks = np.mod(toks, cfg.vocab_size).astype(np.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.kind == "audio":
        batch["frames"] = rng.standard_normal((b, s, cfg.frontend_dim)).astype(np.float32)
    if cfg.kind == "vlm":
        batch["image_embeds"] = rng.standard_normal(
            (b, cfg.num_image_tokens, cfg.vision_dim)).astype(np.float32)
    return batch


def make_batch_iterator(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, step)
        step += 1
