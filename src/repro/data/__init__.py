from repro.data.pipeline import DataConfig, make_batch_iterator, synthetic_batch  # noqa: F401
from repro.data.sparse_datasets import make_url_like_dataset  # noqa: F401
