"""Synthetic high-dimensional sparse classification data (paper §8.2).

Mimics the URL / Webspam datasets: trigram-style features — each sample
touches a tiny subset of a huge feature space, so gradients of linear
models are NATURALLY sparse (the paper's 'lossless' sparsity case)."""
from __future__ import annotations

import numpy as np


def make_url_like_dataset(
    n_samples: int = 4096,
    n_features: int = 1 << 20,
    nnz_per_sample: int = 64,
    seed: int = 0,
):
    """Returns (indices (S, nnz), values (S, nnz), labels (S,) in {-1,+1}).

    Ground truth: a sparse linear separator over a small subset of
    features, so logistic regression is learnable."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, n_features, size=(n_samples, nnz_per_sample)).astype(np.int32)
    val = rng.exponential(1.0, size=(n_samples, nnz_per_sample)).astype(np.float32)
    w_true_idx = rng.choice(n_features, size=2048, replace=False)
    w_true = np.zeros(n_features, np.float32)
    w_true[w_true_idx] = rng.standard_normal(2048)
    margins = (val * w_true[idx]).sum(axis=1)
    labels = np.where(margins + 0.1 * rng.standard_normal(n_samples) > 0, 1.0, -1.0)
    return idx, val, labels.astype(np.float32)
